"""Descriptor-lowering tests (PR 5).

Four concerns:

  * **Parity suite**: the "descriptor" lowering must be BIT-IDENTICAL to
    the "mask" lowering across layouts x reorder strategies x dtypes, for
    SpMV and SpMM, on both the jnp reference path and the Pallas kernels
    (interpret mode) -- the build-time expansion computes exactly the
    quantities the mask decode recomputes, so nothing may change.
  * **Record-store schema v3**: v1/v2/v3 stores round-trip; legacy records
    (no ``lowering`` field) normalise to the mask config identity; the
    tuner distinguishes lowerings and ``ops.prepare`` applies its pick.
  * **Lowering validation**: ``selector.clamp_config`` demotes a
    descriptor config on a layout that registered no descriptor variant,
    and the plan pipeline records the demotion in ``plan.trace``.
  * **Fusion scan**: the panel-layout reorder path issues no standalone
    ``jnp.take`` x-gather any more (the column map is fused into the
    decode / kernels), and the whole-vector descriptor build folds the
    permutation into its static tables outright.

Plus a unit test for the CI perf-regression gate's comparison logic.
"""
import dataclasses
import inspect
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats as F
from repro.core import matgen
from repro.core import plan as P
from repro.core import ref_spmv as R
from repro.core import reorder as RE
from repro.core import selector as S
from repro.kernels import ops

DTYPES = (np.float32, np.float64)
REORDERS = (None, "rcm", "sigma")
LAYOUTS = ("whole_vector", "panels")
GEOM = dict(pr=16, xw=32, cb=8)


@pytest.fixture(autouse=True)
def _no_ambient_store(monkeypatch):
    monkeypatch.delenv(S.RECORDS_ENV, raising=False)
    S.set_default_store(None)
    yield
    S.set_default_store(None)


def bit_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype
    np.testing.assert_array_equal(a, b)


def _pair(mat, layout, dtype, reorder, **kw):
    """(mask plan, descriptor plan) at identical geometry/permutation."""
    mk = lambda low: P.make_plan(mat, layout=layout, dtype=dtype,
                                 lowering=low, reorder=reorder, **GEOM, **kw)
    return mk("mask"), mk("descriptor")


# ----------------------------------------------------------------------------
# Parity suite: descriptor == mask, bitwise
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("reorder", REORDERS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_descriptor_parity_reference(layout, reorder, dtype):
    csr = matgen.scrambled_banded(192, 5, 1.0, seed=7)
    d = csr.to_dense()
    mat = F.csr_to_spc5(csr, 2, 4)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(192).astype(dtype))
    X = jnp.asarray(rng.standard_normal((192, 4)).astype(dtype))
    hm, hd = _pair(mat, layout, dtype, reorder)
    assert hm.lowering == "mask" and hd.lowering == "descriptor"
    ym = ops.spmv(hm, x, use_pallas=False)
    yd = ops.spmv(hd, x, use_pallas=False)
    bit_equal(ym, yd)
    np.testing.assert_allclose(
        np.asarray(ym, np.float64),
        d.astype(np.float64) @ np.asarray(x, np.float64),
        atol=2e-3)
    bit_equal(ops.spmm(hm, X, use_pallas=False),
              ops.spmm(hd, X, use_pallas=False))


@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("reorder", (None, "rcm"))
def test_descriptor_parity_pallas_interpret(layout, reorder):
    csr = matgen.scrambled_banded(160, 4, 1.0, seed=11)
    mat = F.csr_to_spc5(csr, 1, 8)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(160),
                    jnp.float32)
    X = jnp.asarray(np.random.default_rng(3).standard_normal((160, 4)),
                    jnp.float32)
    hm, hd = _pair(mat, layout, np.float32, reorder)
    y_ref = np.asarray(ops.spmv(hd, x, use_pallas=False))
    for db in (False, True):
        for h in (hm, hd):
            y = np.asarray(ops.spmv(h, x, use_pallas=True, interpret=True,
                                    double_buffer=db))
            np.testing.assert_allclose(y, y_ref, atol=1e-5)
    Y_ref = np.asarray(ops.spmm(hd, X, use_pallas=False))
    for h in (hm, hd):
        Y = np.asarray(ops.spmm(h, X, use_pallas=True, interpret=True))
        np.testing.assert_allclose(Y, Y_ref, atol=1e-5)


def test_descriptor_parity_test_split():
    """The beta_test split threads the lowering to its multi sub-plan."""
    csr = matgen.powerlaw(320, 5, seed=13)
    mat = F.csr_to_spc5(csr, 2, 4)
    x = jnp.asarray(np.random.default_rng(4).standard_normal(320),
                    jnp.float32)
    for layout in LAYOUTS:
        hm = ops.prepare(mat, layout="test", multi_layout=layout,
                         dtype=np.float32, lowering="mask", **GEOM)
        hd = ops.prepare(mat, layout="test", multi_layout=layout,
                         dtype=np.float32, lowering="descriptor", **GEOM)
        assert hd.multi.lowering == "descriptor" == hd.lowering
        bit_equal(ops.spmv_test(hm, x, use_pallas=False),
                  ops.spmv_test(hd, x, use_pallas=False))


def test_chunk_descriptors_tables():
    """The expansion's invariants: valid == mask bits, vidx dense per
    chunk, xcol/yrow within the clip bounds, col_map folded statically."""
    csr, _ = matgen.banded(96, 3, 1.0, seed=5), None
    mat = F.csr_to_spc5(csr, 2, 4)
    ch = F.to_chunked(mat, cb=16)
    desc = F.chunk_descriptors(ch.chunk_mask, ch.chunk_voff, ch.chunk_col,
                               ch.chunk_row, r=2, c=4, vmax=ch.vmax,
                               xmax=ch.ncols, ymax=ch.nrows)
    pop = F.popcount_u32(ch.chunk_mask)
    assert np.array_equal(desc.valid.sum(axis=-1), pop)
    assert desc.vidx.min() >= 0 and desc.vidx.max() < ch.vmax
    assert desc.xcol.min() >= 0 and desc.xcol.max() < ch.ncols
    assert desc.yrow.min() >= 0 and desc.yrow.max() < ch.nrows
    # col_map folds into xcol at build time
    cmap = np.random.default_rng(0).permutation(ch.ncols).astype(np.int64)
    desc2 = F.chunk_descriptors(ch.chunk_mask, ch.chunk_voff, ch.chunk_col,
                                ch.chunk_row, r=2, c=4, vmax=ch.vmax,
                                xmax=ch.ncols, ymax=ch.nrows, col_map=cmap)
    assert np.array_equal(desc2.xcol, cmap[desc.xcol])


def test_descriptor_whole_vector_folds_col_perm():
    """Whole-vector descriptor plans carry NO col_perm: the permutation is
    static data in desc_xcol (zero runtime cost)."""
    csr = matgen.scrambled_banded(128, 4, 1.0, seed=17)
    mat = F.csr_to_spc5(csr, 1, 8)
    hd = P.make_plan(mat, layout="whole_vector", cb=32, dtype=np.float32,
                     lowering="descriptor", reorder="rcm")
    assert hd.is_reordered and hd.col_perm is None
    hm = P.make_plan(mat, layout="whole_vector", cb=32, dtype=np.float32,
                     lowering="mask", reorder="rcm")
    assert hm.col_perm is not None
    x = jnp.asarray(np.random.default_rng(6).standard_normal(128),
                    jnp.float32)
    bit_equal(ops.spmv(hm, x, use_pallas=False),
              ops.spmv(hd, x, use_pallas=False))


def test_panel_row_fusion_pure_panel_permutation():
    """A pure panel permutation folds into the stacked panel axis
    (rows_fused) for BOTH lowerings; results stay bit-identical to the
    executor's gather path."""
    nrows = 64
    pr = 16
    csr = matgen.banded(nrows, 5, 1.0, seed=23)
    mat = F.csr_to_spc5(csr, 2, 4)
    # permuted panel order (2, 0, 3, 1): an interval-contiguous, pr-aligned
    # row permutation -- the panel fusion condition
    order = np.array([2, 0, 3, 1])
    row_perm = (order[:, None] * pr + np.arange(pr)[None, :]).reshape(-1)
    reo = RE.Reordering(row_perm.astype(np.int64),
                        np.arange(nrows, dtype=np.int64), strategy="manual")
    assert P._panel_row_permutation(reo, pr, nrows, 4) is not None
    x = jnp.asarray(np.random.default_rng(8).standard_normal(nrows),
                    jnp.float32)
    d = csr.to_dense()
    for low in ("mask", "descriptor"):
        h = P.make_plan(mat, layout="panels", pr=pr, xw=32, cb=8,
                        dtype=np.float32, lowering=low, reorder=reo)
        assert h.rows_fused and h.row_iperm is None
        np.testing.assert_allclose(
            np.asarray(ops.spmv(h, x, use_pallas=False)),
            d.astype(np.float64) @ np.asarray(x, np.float64), atol=2e-3)
    # a non-aligned permutation must NOT fuse
    bad = RE.Reordering(np.roll(np.arange(nrows), 3).astype(np.int64),
                        np.arange(nrows, dtype=np.int64), strategy="manual")
    assert P._panel_row_permutation(bad, pr, nrows, 4) is None


# ----------------------------------------------------------------------------
# Record store: v1/v2/v3 round-trips + tuner arbitration
# ----------------------------------------------------------------------------

def _write_jsonl(path, version, records):
    with open(path, "w") as f:
        f.write(json.dumps({"spc5_records_version": version}) + "\n")
        for r in records:
            f.write(json.dumps(r) + "\n")


def test_record_store_v1_v2_v3_roundtrip(tmp_path):
    base = dict(kernel="1x8", avg=4.0, workers=1, gflops=2.0, matrix="m",
                pr=0, xw=0, cb=512, layout="whole_vector", nnz_row=5.0,
                bandwidth=2.0, fill=0.5)
    v1 = {k: v for k, v in base.items()
          if k not in ("layout",)} | {"layout": "whole"}   # legacy spelling
    v2 = base | {"reorder": "rcm", "bandwidth_post": 1.0, "nchunks": 3}
    v3 = base | {"reorder": "", "bandwidth_post": 0.0, "nchunks": 0,
                 "lowering": "descriptor"}
    _write_jsonl(tmp_path / "v1.jsonl", 1, [v1])
    _write_jsonl(tmp_path / "v2.jsonl", 2, [v2])
    _write_jsonl(tmp_path / "v3.jsonl", 3, [v3])
    store = S.load_records(str(tmp_path))
    assert len(store.records) == 3
    by_low = {r.lowering for r in store.records}
    assert by_low == {"", "descriptor"}
    # legacy records pool with v3 mask measurements: same config identity
    cfgs = {r.config() for r in store.records}
    assert S.PanelConfig("whole_vector", 0, 0, 512) in cfgs          # v1
    assert S.PanelConfig("whole_vector", 0, 0, 512,
                         lowering="descriptor") in cfgs              # v3
    assert all(c.lowering in ("mask", "descriptor") for c in cfgs)
    # round-trip through save_jsonl stamps the current version
    out = tmp_path / "out.jsonl"
    store.save_jsonl(str(out))
    with open(out) as f:
        head = json.loads(f.readline())
    assert head["spc5_records_version"] == S.RECORDS_VERSION == 4
    store2 = S.RecordStore(str(out))
    assert store2.records == store.records
    # a store claiming a NEWER version than supported refuses to load
    _write_jsonl(tmp_path / "v9.jsonl", 9, [v3])
    with pytest.raises(ValueError):
        S._load_jsonl(str(tmp_path / "v9.jsonl"))


def test_tuner_picks_between_lowerings():
    """Planted store: descriptor measures faster -> tune returns the
    descriptor config and ops.prepare applies it."""
    desc_cfg = S.PanelConfig("whole_vector", 0, 0, 32,
                             lowering="descriptor")
    mask_cfg = S.PanelConfig("whole_vector", 0, 0, 32)
    store = S.RecordStore()
    for avg in (1.0, 3.0, 6.0):
        f = S.MatrixFeatures(0, 0, 0, 5.0, 2.0, avg, avg / 8)
        store.add_measurement("1x8", f, desc_cfg, 1, 9.0)
        store.add_measurement("1x8", f, mask_cfg, 1, 1.0)
    feats = S.MatrixFeatures(0, 0, 0, 5.0, 2.0, 4.0, 0.5)
    assert S.tune(feats, store=store, kernel="1x8") == desc_cfg
    csr = matgen.banded(96, 4, 1.0, seed=29)
    h = ops.prepare(F.csr_to_spc5(csr, 1, 8), dtype=np.float32, store=store)
    assert h.lowering == "descriptor"
    assert h.trace[0]["source"] == "store"
    assert h.trace[0]["lowering"] == "descriptor"
    # and the records survive a BENCH-payload round trip (CI artifact shape)
    payload = {"version": S.RECORDS_VERSION,
               "records": [dataclasses.asdict(r) for r in store.records]}
    assert all(S.Record(**r).config() in (desc_cfg, mask_cfg)
               for r in payload["records"])


def test_clamp_config_demotes_unregistered_lowering():
    """Satellite: a layout without a descriptor variant demotes tuned
    descriptor configs to mask, and the plan pipeline traces it."""
    spec = P._REGISTRY[P.LAYOUT_WHOLE]
    P._REGISTRY[P.LAYOUT_WHOLE] = dataclasses.replace(
        spec, lowerings=(P.LOWERING_MASK,))
    try:
        cfg = S.clamp_config(
            S.PanelConfig("whole_vector", 0, 0, 64, lowering="descriptor"),
            nrows=96, ncols=96, r=1, c=8, nblocks=10)
        assert cfg.lowering == "mask"
        csr = matgen.banded(96, 4, 1.0, seed=31)
        h = ops.prepare(F.csr_to_spc5(csr, 1, 8), dtype=np.float32, cb=32,
                        layout="whole_vector", lowering="descriptor")
        assert h.lowering == "mask"
        lay = [e for e in h.trace if e["pass"] == "layout"][0]
        assert lay["lowering_demoted"] is True
    finally:
        P._REGISTRY[P.LAYOUT_WHOLE] = spec
    # unknown lowering names never enter configs at all
    with pytest.raises(ValueError):
        S.PanelConfig("whole_vector", lowering="csr5")


def test_shard_plan_serves_descriptor():
    """An explicit descriptor request survives sharding: the layout's
    shard_build_desc hook stacks descriptor tables (no demotion, no
    mask arrays) and the trace records the requested resolution."""
    from repro.core import distributed as D
    from repro.core import ref_spmv as R

    csr = matgen.banded(144, 5, 1.0, seed=37)
    sh = D.shard_matrix(F.csr_to_spc5(csr, 1, 8), 2, cb=32, tune=False,
                        lowering="descriptor")
    sentry = sh.trace[-1]
    assert sentry["pass"] == "shard"
    assert sentry["lowering"] == "descriptor"
    assert "lowering_demoted" not in sentry
    lentry = [e for e in sh.trace if e.get("pass") == "lowering"][0]
    assert lentry["reason"] == "requested"
    # the stacked arrays resolve by the DESCRIPTOR name set
    assert len(sh.arrays) == len(R.SPC5DescDevice._fields)
    assert sh.desc_valid.shape == sh.desc_vidx.shape


# ----------------------------------------------------------------------------
# Fusion scan: no standalone x-gather on the panel reorder path
# ----------------------------------------------------------------------------

def test_panel_lowering_has_no_standalone_x_gather():
    """PR-4-style dispatch scan, for the fusion acceptance criterion: the
    panel lowerings pass x straight through with the column map fused into
    the decode -- no ``_gathered_x`` materialisation, no ``jnp.take(x``."""
    for fn in (P._lower_spmv_panels, P._lower_spmm_panels):
        src = inspect.getsource(fn)
        assert "_gathered_x(" not in src, fn.__name__
        assert "jnp.take(x" not in src, fn.__name__
    # the reference panel decode routes the gather through cmap instead of
    # consuming a pre-permuted x
    for fn in (R.spmv_panels, R.spmm_panels, R.spmv_panels_desc,
               R.spmm_panels_desc):
        assert "cmap" in inspect.signature(fn).parameters or \
            "cmap" in inspect.getsource(fn), fn.__name__


def test_panel_fused_x_vmem_guard(monkeypatch):
    """Past the VMEM budget the pallas panel lowerings fall back to the
    materialised gather (bounded windowed-DMA footprint) instead of
    holding a too-large x + map VMEM-resident; results are unchanged."""
    csr = matgen.scrambled_banded(160, 4, 1.0, seed=43)
    mat = F.csr_to_spc5(csr, 1, 8)
    h = P.make_plan(mat, layout="panels", pr=16, xw=32, cb=8,
                    dtype=np.float32, lowering="mask", reorder="rcm")
    assert h.col_perm is not None
    x = jnp.asarray(np.random.default_rng(10).standard_normal(160),
                    jnp.float32)
    xk, cmap = P._panel_fused_x(h, x)
    assert cmap is not None and xk is x          # fits: fused path
    y_fused = np.asarray(ops.spmv(h, x, use_pallas=True, interpret=True))
    monkeypatch.setattr(P, "VMEM_WHOLE_VECTOR_BUDGET", 64)
    xk, cmap = P._panel_fused_x(h, x)
    assert cmap is None and xk is not x          # too big: materialised
    y_guard = np.asarray(ops.spmv(h, x, use_pallas=True, interpret=True))
    np.testing.assert_allclose(y_guard, y_fused, atol=1e-6)


def test_panel_fused_cmap_matches_materialised_gather():
    """The fused panel path == the old materialised-gather computation,
    bitwise (reference) and numerically (Pallas interpret)."""
    csr = matgen.scrambled_banded(160, 4, 1.0, seed=41)
    mat = F.csr_to_spc5(csr, 1, 8)
    reo = RE.reorder(mat, "rcm", r=1, c=8, pr=16, xw=32, cb=8)
    assert not reo.is_identity and not reo.identity_cols
    h = P.make_plan(mat, layout="panels", pr=16, xw=32, cb=8,
                    dtype=np.float32, lowering="mask", reorder=reo)
    assert h.col_perm is not None
    x = jnp.asarray(np.random.default_rng(9).standard_normal(160),
                    jnp.float32)
    # old path: materialise permuted x, no cmap
    pm = reo.permute_spc5(mat)
    pan = F.to_panels(pm, pr=16, cb=8, xw=32)
    dev = R.device_put_panels(pan, dtype=np.float32)
    xg = jnp.take(x, jnp.asarray(reo.col_perm.astype(np.int32)), axis=0)
    y_old = R.spmv_panels(dev, xg, r=1, c=8, pr=pan.pr, nrows=160,
                          ncols_pad=pan.ncols_pad)
    if not reo.identity_rows:
        y_old = jnp.take(y_old,
                         jnp.asarray(reo.row_iperm.astype(np.int32)), axis=0)
    bit_equal(ops.spmv(h, x, use_pallas=False), y_old)
    y_pal = np.asarray(ops.spmv(h, x, use_pallas=True, interpret=True))
    np.testing.assert_allclose(y_pal, np.asarray(y_old), atol=1e-5)


# ----------------------------------------------------------------------------
# Perf-regression gate logic
# ----------------------------------------------------------------------------

def test_regression_gate_compare():
    from benchmarks.regression_gate import compare, section_gflops

    def payload(scale):
        return {"sections": {
            "spmv_seq": [f"spmv_seq.m.k{i},1.0,gflops={scale * (1 + i)}"
                         for i in range(6)],
            "tiny": ["tiny.x,1.0,gflops=1.0"],          # < min_lines: skip
        }}

    assert section_gflops(payload(1.0))["spmv_seq"] == [1.0, 2.0, 3.0, 4.0,
                                                        5.0, 6.0]
    # same perf: pass
    assert compare(payload(1.0), payload(1.0)) == []
    # 10% faster: pass; 50% slower: fail; new section with no prior: skip
    assert compare(payload(1.1), payload(1.0)) == []
    failures = compare(payload(0.5), payload(1.0))
    assert len(failures) == 1 and "spmv_seq" in failures[0]
    cur = payload(0.5)
    cur["sections"]["brand_new"] = ["brand_new.x,1,gflops=1"] * 6
    assert len(compare(cur, payload(1.0))) == 1     # new section skipped
    # within threshold (20% drop < 25%): pass
    assert compare(payload(0.8), payload(1.0)) == []
