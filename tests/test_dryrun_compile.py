"""End-to-end dry-run compile smoke (512 placeholder devices, subprocess).

Compiles the fastest cell (mamba2 decode) for BOTH production meshes --
guards the launch path (mesh construction, sharding specs, lower+compile,
HLO analysis, JSON record) against regressions. ~60 s.
"""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_dryrun_cell_compiles_both_meshes(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-370m", "--shape", "decode_32k",
         "--both-meshes", "--out-dir", str(tmp_path), "--tag", "smoke"],
        capture_output=True, text=True, env=env, timeout=570,
        cwd=REPO)
    assert res.returncode == 0, res.stderr[-2000:]
    recs = sorted(os.listdir(tmp_path))
    assert len(recs) == 2
    for name in recs:
        with open(tmp_path / name) as f:
            rec = json.load(f)
        assert "skipped" not in rec
        assert rec["hlo"]["flops_per_device"] > 0
        assert rec["memory"]["peak_bytes_per_device"] > 0
        assert rec["compile_s"] > 0
        assert rec["n_devices"] in (256, 512)
