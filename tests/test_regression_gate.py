"""benchmarks/regression_gate.py edge cases: degraded artifacts must skip
with a note, never crash or false-fail the gate."""
import json
import math

import pytest

from benchmarks import regression_gate as G


def payload(**sections):
    return {"sections": {k: list(v) for k, v in sections.items()}}


def lines(*gflops):
    return [f"bench nnz=100 gflops={g}" for g in gflops]


def test_section_gflops_filters_unparseable_lines():
    p = payload(a=["no measurement here",
                   "bench gflops=nan", "bench gflops=0",
                   "bench gflops=-3.0", "bench gflops=1e999",
                   "bench gflops=2.0"])
    vals = G.section_gflops(p)
    assert vals == {"a": [2.0]}
    assert all(math.isfinite(v) and v > 0 for v in vals["a"])


def test_empty_prior_section_skips(capsys):
    cur = payload(a=lines(*[2.0] * 6))
    pri = payload(a=[])                  # section present but no lines
    assert G.compare(cur, pri) == []
    assert "no prior" in capsys.readouterr().out


def test_all_nan_prior_section_skips(capsys):
    cur = payload(a=lines(*[2.0] * 6))
    pri = payload(a=["bench gflops=nan"] * 6)
    assert G.compare(cur, pri) == []
    assert "no prior" in capsys.readouterr().out


def test_new_current_section_notes_and_passes(capsys):
    # a fresh section (e.g. a new vdtype bench) must skip-with-note, not
    # fail its introducing PR
    cur = payload(a=lines(*[2.0] * 6), fresh_bf16=lines(*[3.0] * 6))
    pri = payload(a=lines(*[2.0] * 6))
    assert G.compare(cur, pri) == []
    out = capsys.readouterr().out
    assert "NEW in the current run" in out and "no prior baseline" in out


def test_prior_only_section_notes_and_passes(capsys):
    cur = payload(a=lines(*[2.0] * 6))
    pri = payload(a=lines(*[2.0] * 6), removed=lines(*[9.0] * 6))
    assert G.compare(cur, pri) == []
    out = capsys.readouterr().out
    assert "'removed' missing in current -- skipped" in out


def test_regression_still_fails():
    cur = payload(a=lines(*[1.0] * 6))
    pri = payload(a=lines(*[2.0] * 6))
    failures = G.compare(cur, pri, threshold=0.25)
    assert len(failures) == 1 and "regressed" in failures[0]


def test_min_lines_skip(capsys):
    cur = payload(a=lines(1.0, 1.0))
    pri = payload(a=lines(9.0, 9.0))
    assert G.compare(cur, pri, min_lines=5) == []
    assert "<5 lines" in capsys.readouterr().out


def test_main_exit_codes(tmp_path):
    cur, pri = tmp_path / "cur.json", tmp_path / "pri.json"
    cur.write_text(json.dumps(payload(a=lines(*[2.0] * 6))))
    pri.write_text(json.dumps(payload(a=lines(*[2.0] * 6),
                                      gone=lines(*[5.0] * 6))))
    assert G.main(["--current", str(cur), "--prior", str(pri)]) == 0
    pri.write_text(json.dumps(payload(a=lines(*[9.0] * 6))))
    assert G.main(["--current", str(cur), "--prior", str(pri)]) == 1


@pytest.mark.parametrize("bad", ["nan", "inf", "-inf", "none", ""])
def test_degenerate_gflops_values_do_not_crash(bad):
    p = payload(a=[f"bench gflops={bad}"] * 6)
    assert G.compare(p, p) == []
    assert G.compare(payload(a=lines(*[2.0] * 6)), p) == []
