"""Per-kernel allclose vs the pure-jnp oracle, sweeping shapes and dtypes.

Pallas kernels run in interpret=True on CPU (the kernel body executes in
Python), exactly as the assignment prescribes for kernel validation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro._compat.hypothesis import given, settings, strategies as st

from repro.core import formats as F
from repro.core import matgen
from repro.kernels import ops, ref

BLOCKS = [(1, 8), (2, 4), (2, 8), (4, 4), (4, 8), (8, 4)]


def make_handle(n, m, density, rc, seed, dtype=np.float32, cb=32):
    rng = np.random.default_rng(seed)
    d = ((rng.random((n, m)) < density)
         * rng.standard_normal((n, m))).astype(dtype)
    csr = F.csr_from_dense(d)
    mat = F.csr_to_spc5(csr, *rc)
    return d, ops.prepare(mat, cb=cb)


@pytest.mark.parametrize("rc", BLOCKS)
def test_spmv_pallas_vs_oracle(rc):
    d, h = make_handle(96, 80, 0.12, rc, seed=sum(rc))
    x = np.random.default_rng(1).standard_normal(80).astype(np.float32)
    tgt = d.astype(np.float64) @ x.astype(np.float64)
    y_ref = ops.spmv(h, jnp.asarray(x), use_pallas=False)
    y_pal = ops.spmv(h, jnp.asarray(x), use_pallas=True, interpret=True,
                     double_buffer=False)
    y_db = ops.spmv(h, jnp.asarray(x), use_pallas=True, interpret=True,
                    double_buffer=True)
    np.testing.assert_allclose(np.asarray(y_ref), tgt, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(y_db), np.asarray(y_ref),
                               atol=1e-6)


@pytest.mark.parametrize("rc", [(1, 8), (4, 4), (8, 4)])
@pytest.mark.parametrize("nvec,nvt", [(4, 4), (16, 8)])
def test_spmm_pallas_vs_oracle(rc, nvec, nvt):
    d, h = make_handle(64, 72, 0.2, rc, seed=7)
    X = np.random.default_rng(2).standard_normal((72, nvec)).astype(np.float32)
    tgt = d.astype(np.float64) @ X.astype(np.float64)
    Y_ref = ops.spmm(h, jnp.asarray(X), use_pallas=False)
    Y_pal = ops.spmm(h, jnp.asarray(X), use_pallas=True, interpret=True,
                     nvt=nvt)
    np.testing.assert_allclose(np.asarray(Y_ref), tgt, atol=5e-4)
    # kernel unrolls (r, c) adds; oracle uses one einsum -- association only
    np.testing.assert_allclose(np.asarray(Y_pal), np.asarray(Y_ref),
                               atol=2e-5, rtol=2e-5)


def test_spmv_f32():
    d, h = make_handle(50, 60, 0.3, (2, 8), seed=3, dtype=np.float32)
    x = np.random.default_rng(3).standard_normal(60).astype(np.float32)
    y = ops.spmv(h, jnp.asarray(x), use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(y).astype(np.float64),
                               d.astype(np.float64) @ x.astype(np.float64),
                               atol=2e-4)


def test_spmv_f64_x64_mode():
    """f64 path needs jax x64 (global flag) -> isolated subprocess."""
    import os, subprocess, sys
    code = (
        "import jax; jax.config.update('jax_enable_x64', True)\n"
        "import numpy as np, jax.numpy as jnp\n"
        "from repro.core import formats as F\n"
        "from repro.kernels import ops\n"
        "rng = np.random.default_rng(0)\n"
        "d = ((rng.random((50,60)) < 0.3)"
        " * rng.standard_normal((50,60)))\n"
        "h = ops.prepare(F.csr_to_spc5(F.csr_from_dense(d), 2, 8), cb=32)\n"
        "x = rng.standard_normal(60)\n"
        "y = ops.spmv(h, jnp.asarray(x), use_pallas=True, interpret=True)\n"
        "np.testing.assert_allclose(np.asarray(y), d @ x, atol=1e-10)\n"
        "print('OK')\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=300)
    assert res.returncode == 0, res.stderr


def test_spmv_bf16():
    import dataclasses
    d, h = make_handle(40, 40, 0.3, (1, 8), seed=4)
    hb = dataclasses.replace(
        h, arrays=tuple(a.astype(jnp.bfloat16) if a.dtype == jnp.float32
                        else a for a in h.arrays))
    x = jnp.asarray(np.random.default_rng(5).standard_normal(40),
                    dtype=jnp.bfloat16)
    y = ops.spmv(hb, x, use_pallas=True, interpret=True)
    tgt = d.astype(np.float64) @ np.asarray(x, np.float64)
    np.testing.assert_allclose(np.asarray(y, np.float64), tgt,
                               atol=0.15 * (np.abs(tgt).max() + 1))


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(8, 64),
    m=st.integers(8, 64),
    density=st.floats(0.02, 0.5),
    rc=st.sampled_from(BLOCKS),
    cb=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**20),
)
def test_property_kernel_matches_oracle(n, m, density, rc, cb, seed):
    d, h = make_handle(n, m, density, rc, seed, cb=cb)
    x = np.random.default_rng(seed + 1).standard_normal(m).astype(np.float32)
    y_ref = np.asarray(ops.spmv(h, jnp.asarray(x), use_pallas=False))
    y_pal = np.asarray(ops.spmv(h, jnp.asarray(x), use_pallas=True,
                                interpret=True))
    np.testing.assert_allclose(y_pal, y_ref, atol=1e-6)
    np.testing.assert_allclose(
        y_ref, d.astype(np.float64) @ x.astype(np.float64), atol=5e-4)


@pytest.mark.parametrize("rc", [(1, 8), (2, 4)])
def test_beta_test_split_kernel(rc):
    """beta(r,c)_test: singleton COO tail + block kernel == full product."""
    from repro.core import matgen
    csr = matgen.powerlaw(600, 5, seed=9)
    d = csr.to_dense()
    mat = F.csr_to_spc5(csr, *rc)
    ht = ops.prepare(mat, layout="test", cb=64, dtype=np.float32)
    assert ht.single_values.shape[0] > 0   # power-law has singletons
    x = np.random.default_rng(1).standard_normal(600).astype(np.float32)
    y = ops.spmv_test(ht, jnp.asarray(x), use_pallas=False)
    tgt = d @ x
    np.testing.assert_allclose(np.asarray(y), tgt,
                               atol=2e-4 * max(1, np.abs(tgt).max()))
    # and through the Pallas kernel for the multi part
    y2 = ops.spmv_test(ht, jnp.asarray(x), use_pallas=True, interpret=True,
                       double_buffer=False)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y), atol=1e-5)


def test_empty_and_edge_matrices():
    # all-zero matrix
    d = np.zeros((16, 16), np.float32)
    csr = F.csr_from_dense(d)
    mat = F.csr_to_spc5(csr, 2, 4)
    h = ops.prepare(mat, cb=8)
    y = ops.spmv(h, jnp.ones(16), use_pallas=False)
    np.testing.assert_allclose(np.asarray(y), 0.0)
    # single element at the far corner
    d[15, 15] = 3.0
    mat = F.csr_to_spc5(F.csr_from_dense(d), 4, 8)
    h = ops.prepare(mat, cb=8)
    y = ops.spmv(h, jnp.ones(16), use_pallas=True, interpret=True)
    assert np.asarray(y)[15] == pytest.approx(3.0)
