"""SPC5 format tests: round-trips, occupancy model, stats, chunking."""
import numpy as np
import pytest
from repro._compat.hypothesis import given, settings, strategies as st

from repro.core import formats as F
from repro.core import matgen


def rand_dense(n, m, density, seed=0, dtype=np.float64):
    rng = np.random.default_rng(seed)
    return ((rng.random((n, m)) < density)
            * rng.standard_normal((n, m))).astype(dtype)


@pytest.mark.parametrize("rc", F.SUPPORTED_BLOCKS)
@pytest.mark.parametrize("density", [0.02, 0.15, 0.6])
def test_roundtrip_dense(rc, density):
    d = rand_dense(57, 43, density, seed=hash(rc) % 100)
    csr = F.csr_from_dense(d)
    mat = F.csr_to_spc5(csr, *rc)
    np.testing.assert_allclose(mat.to_dense(), d)
    assert mat.nnz == csr.nnz  # NO zero padding in values
    assert mat.values.shape[0] == csr.nnz


@pytest.mark.parametrize("rc", [(1, 8), (2, 4), (4, 8), (8, 4)])
def test_block_stats_match_conversion(rc):
    csr = matgen.banded(500, 5, 0.9, seed=1)
    nb, avg = F.block_stats(csr, *rc)
    mat = F.csr_to_spc5(csr, *rc)
    assert nb == mat.nblocks
    assert avg == pytest.approx(mat.avg_nnz_per_block)


def test_occupancy_eq2_matches_measured():
    csr = matgen.fem_blocks(600, 4, 6, seed=2)
    for rc in [(1, 8), (4, 4), (8, 4)]:
        mat = F.csr_to_spc5(csr, *rc)
        model = F.occupancy_model_spc5(
            mat.nnz, mat.nrows, mat.avg_nnz_per_block, *rc,
            s_float=mat.values.dtype.itemsize)
        measured = mat.occupancy_bytes()
        assert measured == pytest.approx(model, rel=0.05)


def test_occupancy_beats_csr_when_filled():
    """Paper eq. (4): beta beats CSR when Avg(r,c) > 1 + r*c/(8*S_int)."""
    csr = matgen.fem_blocks(600, 8, 6, seed=3)  # dense 8x8 blocks
    mat = F.csr_to_spc5(csr, 4, 8)
    assert mat.avg_nnz_per_block > F.beta_breakeven_avg(4, 8)
    assert mat.occupancy_bytes() < csr.occupancy_bytes()


def test_dense_matrix_fully_filled():
    csr = matgen.dense(64, seed=4)
    for rc in [(1, 8), (2, 8), (4, 8)]:
        mat = F.csr_to_spc5(csr, *rc)
        assert mat.fill_ratio == pytest.approx(1.0)


def test_singleton_split_preserves_matrix():
    csr = matgen.powerlaw(800, 6, seed=5)
    mat = F.csr_to_spc5(csr, 1, 8)
    ts = F.split_singletons(mat)
    d = ts.multi.to_dense()
    np.add.at(d, (ts.single_rows, ts.single_cols), ts.single_values)
    np.testing.assert_allclose(d, csr.to_dense())
    assert ts.nnz == mat.nnz
    # powerlaw matrices should have plenty of singleton blocks
    assert ts.single_values.shape[0] > 0


def test_chunked_layout_alignment():
    csr = matgen.banded(400, 7, 0.8, seed=6)
    mat = F.csr_to_spc5(csr, 2, 8)
    ch = F.to_chunked(mat, cb=32, align=8)
    assert np.all(ch.chunk_vbase % 8 == 0)
    assert ch.vmax % 8 == 0
    # padding overhead stays tiny (chunk-alignment only, <2%)
    assert ch.values.shape[0] <= mat.nnz * 1.02 + ch.vmax + 8
    # masks of padding blocks are zero
    nblocks = mat.nblocks
    flat_mask = ch.chunk_mask.reshape(-1)
    assert np.all(flat_mask[nblocks:] == 0)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 80),
    m=st.integers(4, 80),
    density=st.floats(0.01, 0.7),
    rc=st.sampled_from(list(F.SUPPORTED_BLOCKS)),
    seed=st.integers(0, 2**20),
)
def test_property_roundtrip_and_occupancy(n, m, density, rc, seed):
    d = rand_dense(n, m, density, seed=seed)
    csr = F.csr_from_dense(d)
    mat = F.csr_to_spc5(csr, *rc)
    # invariant 1: exact reconstruction
    np.testing.assert_allclose(mat.to_dense(), d)
    # invariant 2: values exactly the nonzeros, no padding
    assert mat.values.shape[0] == csr.nnz
    # invariant 3: popcounts partition the values array
    assert int(F.popcount_u32(mat.block_masks).sum()) == mat.nnz
    # invariant 4: rowptr monotone
    assert np.all(np.diff(mat.block_rowptr) >= 0)
    # invariant 5: blocks stay in bounds
    if mat.nblocks:
        assert mat.block_colidx.min() >= 0
        assert mat.block_colidx.max() <= max(m - 1, 0)


def test_csr_from_coo_duplicates_summed():
    rows = np.array([0, 0, 1])
    cols = np.array([1, 1, 0])
    vals = np.array([2.0, 3.0, 1.0])
    csr = F.csr_from_coo((2, 2), rows, cols, vals)
    d = csr.to_dense()
    assert d[0, 1] == 5.0 and d[1, 0] == 1.0
