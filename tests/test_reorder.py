"""Reordering subsystem tests (repro.core.reorder / structure + ops wiring).

The core contract under test: for every strategy and every layout,
``ops.prepare(reorder=...)`` returns a plan whose spmv/spmm equals the
dense product ON THE ORIGINAL MATRIX -- the permutation must be invisible
to callers (x in, y out, both in original index order), whether the
gather/scatter runs as explicit jnp.take or fused into the kernels' index
arrays (whole-vector col_map / interval-contiguous chunk_row).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro._compat.hypothesis import given, settings, strategies as st

from repro.core import formats as F
from repro.core import matgen
from repro.core import reorder as RE
from repro.core import selector as S
from repro.core import structure as ST
from repro.kernels import ops

GEOM = dict(pr=32, xw=64, cb=8)          # window-bound at test sizes


def scrambled(dim=240, band=6, seed=5):
    return matgen.scrambled_banded(dim, band, 1.0, seed=seed)


def perm_is_valid(perm, n):
    return sorted(perm.tolist()) == list(range(n))


# ----------------------------------------------------------------------------
# Reordering object + strategies
# ----------------------------------------------------------------------------

def test_permutation_algebra():
    csr = scrambled(120)
    reo = RE.reorder(csr, "rcm", r=2, c=4, **GEOM)
    assert perm_is_valid(reo.row_perm, 120)
    assert perm_is_valid(reo.col_perm, 120)
    d = csr.to_dense()
    x = np.random.default_rng(0).standard_normal(120)
    # A' @ x[col_perm] == (A @ x)[row_perm]; unpermute_y undoes it
    dp = reo.permute_csr(csr).to_dense()
    np.testing.assert_allclose(dp @ reo.apply_x(x), (d @ x)[reo.row_perm])
    np.testing.assert_allclose(reo.unpermute_y((d @ x)[reo.row_perm]), d @ x)
    # permute_spc5 rebuilds blocks on the permuted pattern
    mat = F.csr_to_spc5(csr, 2, 4)
    np.testing.assert_allclose(reo.permute_spc5(mat).to_dense(), dp)


@pytest.mark.parametrize("strategy", RE.STRATEGIES)
def test_strategy_permutations_valid_and_deterministic(strategy):
    csr = scrambled(200)
    a = RE.reorder(csr, strategy, r=1, c=8, **GEOM)
    b = RE.reorder(csr, strategy, r=1, c=8, **GEOM)
    assert np.array_equal(a.row_perm, b.row_perm)
    assert np.array_equal(a.col_perm, b.col_perm)
    assert a.strategy == b.strategy and a.stats == b.stats
    assert perm_is_valid(a.row_perm, 200) and perm_is_valid(a.col_perm, 200)
    assert {"bw_pre", "bw_post", "nchunks_pre", "nchunks_post",
            "applied"} <= set(a.stats)


def test_sigma_windows_bound_row_travel():
    """sigma-sorted rows never leave their sigma-window (the SELL-C-sigma
    locality property), and sorting is by descending nnz within windows."""
    csr = matgen.uniform_random(96, 4, seed=3)
    reo = RE.sigma_window_rows(csr, sigma=17, pr=8)      # rounds up to 24
    sigma = int(reo.stats["sigma"])
    assert sigma == 24
    nnz = np.diff(csr.rowptr)
    for w0 in range(0, 96, sigma):
        win = reo.row_perm[w0:w0 + sigma]
        assert win.min() >= w0 and win.max() < w0 + sigma
        lens = nnz[win]
        assert np.all(lens[:-1] >= lens[1:])             # descending


def test_rcm_recovers_scrambled_band():
    csr = scrambled(300, band=5, seed=9)
    reo = RE.reorder(csr, "rcm", r=1, c=8, **GEOM)
    assert reo.stats["applied"] == 1.0
    assert reo.stats["bw_post"] < reo.stats["bw_pre"] / 5
    assert reo.stats["nchunks_post"] < reo.stats["nchunks_pre"]
    # interval-level permutation stays fusable for r > 1 blocks
    reo2 = RE.rcm_blocks(csr, r=2, c=4)
    assert reo2.rows_interval_contiguous(2)


def test_reorder_declines_without_improvement():
    """On an already-banded matrix RCM/colwindow cannot improve the chunk
    count; the driver must return the identity with the evidence."""
    csr = matgen.banded(256, 4, 1.0, seed=1)
    reo = RE.reorder(csr, "rcm", r=1, c=8, **GEOM)
    if reo.stats["declined"]:
        assert reo.is_identity
        assert reo.stats["nchunks_post"] == reo.stats["nchunks_pre"]
    else:       # if it applied, it must have strictly improved
        assert (reo.stats["nchunks_post"], reo.stats["bw_post"]) \
            < (reo.stats["nchunks_pre"], reo.stats["bw_pre"])
    bad = RE.reorder(csr, "auto", r=1, c=8, **GEOM)
    assert bad.stats["nchunks_post"] <= bad.stats["nchunks_pre"]


def test_reorder_empty_and_tiny():
    empty = F.csr_from_dense(np.zeros((8, 8), np.float32))
    reo = RE.reorder(empty, "auto", **GEOM)
    assert reo.is_identity and reo.nrows == 8
    one = F.csr_from_dense(np.eye(1, dtype=np.float32))
    for strat in ("sigma", "rcm", "colwindow", "auto", "none"):
        r1 = RE.reorder(one, strat, **GEOM)
        assert perm_is_valid(r1.row_perm, 1) and perm_is_valid(r1.col_perm, 1)
    # 1-row matrices and unknown strategies
    with pytest.raises(ValueError):
        RE.reorder(one, "definitely-not-a-strategy")


# ----------------------------------------------------------------------------
# structure.profile
# ----------------------------------------------------------------------------

def test_profile_reports_structure():
    csr = matgen.banded(128, 4, 1.0, seed=2)
    prof = ST.profile(csr, r=1, c=8, pr=16, xw=32, cb=8)
    assert prof.nnz == csr.nnz and prof.nrows == 128
    assert prof.bandwidth_mean < 4 and prof.diag_frac > 0.1
    assert prof.panel_chunks.shape == (8,)
    assert prof.nchunks_total == int(prof.panel_chunks.sum())
    # chunk counts match what to_panels actually builds
    mat = F.csr_to_spc5(csr, 1, 8)
    pan = F.to_panels(mat, pr=16, cb=8, xw=32)
    real = (pan.chunk_mask.any(axis=-1)).sum(axis=1)
    np.testing.assert_array_equal(prof.panel_chunks, real)
    # features() feeds the selector
    feats = prof.features("1x8")
    assert isinstance(feats, S.MatrixFeatures)
    assert feats.nnz == csr.nnz and feats.avg > 1.0
    assert "nchunks" in prof.summary()


def test_profile_diag_dominance():
    d = np.diag(np.full(16, 10.0)).astype(np.float32)
    d[3, 7] = 1.0
    prof = ST.profile(F.csr_from_dense(d), pr=8, xw=16, cb=4)
    assert prof.diag_frac == 1.0 and prof.diag_dominance == 1.0


# ----------------------------------------------------------------------------
# ops integration: the permutation must be invisible to callers
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ("sigma", "rcm", "colwindow", "auto"))
@pytest.mark.parametrize("layout", ("whole", "panels"))
def test_roundtrip_all_strategies_and_layouts(strategy, layout):
    csr = scrambled(160, band=6, seed=11)
    d = csr.to_dense()
    x = np.random.default_rng(1).standard_normal(160).astype(np.float32)
    tgt = d.astype(np.float64) @ x.astype(np.float64)
    for rc in ((1, 8), (2, 4), (4, 4)):
        mat = F.csr_to_spc5(csr, *rc)
        h = ops.prepare(mat, layout=layout, dtype=np.float32,
                        reorder=strategy, **GEOM)
        y = np.asarray(ops.spmv(h, jnp.asarray(x), use_pallas=False))
        np.testing.assert_allclose(y, tgt, atol=2e-3)
        X = np.random.default_rng(2).standard_normal((160, 4)).astype(np.float32)
        Y = np.asarray(ops.spmm(h, jnp.asarray(X), use_pallas=False))
        np.testing.assert_allclose(Y, d @ X, atol=5e-3)


def test_fused_pallas_paths_match_oracle():
    """Whole-vector Pallas kernels with fused col_map + fused chunk_row
    scatter vs the (already-verified) jnp path and the dense oracle.
    Pinned to the mask lowering: the descriptor lowering folds the column
    permutation into its static tables instead (col_perm is None there --
    covered by tests/test_descriptor.py)."""
    csr = scrambled(160, band=6, seed=13)
    d = csr.to_dense()
    x = np.random.default_rng(3).standard_normal(160).astype(np.float32)
    tgt = d.astype(np.float64) @ x.astype(np.float64)
    mat = F.csr_to_spc5(csr, 2, 4)
    h = ops.prepare(mat, layout="whole_vector", dtype=np.float32,
                    reorder="rcm", lowering="mask")
    assert h.is_reordered
    assert h.rows_fused and h.row_iperm is None     # scatter fused away
    assert h.col_perm is not None
    for db in (False, True):
        y = np.asarray(ops.spmv(h, jnp.asarray(x), use_pallas=True,
                                interpret=True, double_buffer=db))
        np.testing.assert_allclose(y, tgt, atol=2e-3)
    X = np.random.default_rng(4).standard_normal((160, 4)).astype(np.float32)
    Y = np.asarray(ops.spmm(h, jnp.asarray(X), use_pallas=True,
                            interpret=True, nvt=4))
    np.testing.assert_allclose(Y, d @ X, atol=5e-3)
    # panel layout: fused col_map decode (PR 5; no materialised x gather)
    hp = ops.prepare(mat, layout="panels", dtype=np.float32, reorder="rcm",
                     **GEOM)
    if hp.is_reordered:
        yp = np.asarray(ops.spmv(hp, jnp.asarray(x), use_pallas=True,
                                 interpret=True))
        np.testing.assert_allclose(yp, tgt, atol=2e-3)


def test_reordered_handle_pytree_and_stats():
    mat = F.csr_to_spc5(scrambled(96, band=4, seed=7), 1, 8)
    h = ops.prepare(mat, layout="whole_vector", dtype=np.float32,
                    reorder="rcm")
    assert h.is_reordered
    assert h.shape == (96, 96) and h.nnz == mat.nnz
    assert h.stats["applied"] == 1.0
    flat, tdef = jax.tree.flatten(h)
    h2 = jax.tree.unflatten(tdef, flat)
    x = jnp.ones((96,), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.spmv(h2, x, use_pallas=False)),
                               np.asarray(ops.spmv(h, x, use_pallas=False)))


def test_prepare_reorder_none_and_declined_stay_plain():
    mat = F.csr_to_spc5(matgen.banded(128, 4, 1.0, seed=1), 1, 8)
    h0 = ops.prepare(mat, layout="whole_vector")
    assert h0.layout == ops.LAYOUT_WHOLE and not h0.is_reordered
    h = ops.prepare(mat, layout="whole_vector", reorder="none")
    assert not h.is_reordered                   # explicit no-op
    # legacy layout spelling still accepted by the wrapper
    assert ops.prepare(mat, layout="whole").layout == ops.LAYOUT_WHOLE
    with pytest.raises(ValueError):             # shape-mismatched Reordering
        ops.prepare(mat, reorder=RE.identity((4, 4)))


def test_test_split_panel_tail_and_reorder():
    """beta_test: panel-bucketed COO tail equals the whole-vector tail, and
    composes with reordering."""
    csr = matgen.uniform_random(256, 5, seed=21)
    d = csr.to_dense()
    x = np.random.default_rng(5).standard_normal(256).astype(np.float32)
    tgt = d.astype(np.float64) @ x.astype(np.float64)
    mat = F.csr_to_spc5(csr, 2, 4)
    hw = ops.prepare(mat, layout="test", cb=64, dtype=np.float32)
    assert hw.tail_pr == 0
    hp = ops.prepare(mat, layout="test", multi_layout="panels",
                     dtype=np.float32, **GEOM)
    assert hp.tail_pr == GEOM["pr"] and hp.single_rows.ndim == 2
    assert hp.single_rows.shape[0] == hp.multi.npanels
    yw = np.asarray(ops.spmv_test(hw, jnp.asarray(x), use_pallas=False))
    yp = np.asarray(ops.spmv_test(hp, jnp.asarray(x), use_pallas=False))
    np.testing.assert_allclose(yw, tgt, atol=2e-3)
    np.testing.assert_allclose(yp, yw, atol=1e-5)
    hr = ops.prepare(mat, layout="test", multi_layout="panels",
                     dtype=np.float32, reorder="sigma", **GEOM)
    yr = np.asarray(ops.spmv_test(hr, jnp.asarray(x), use_pallas=False))
    np.testing.assert_allclose(yr, tgt, atol=2e-3)


def test_distributed_reorder_roundtrip():
    from repro.core import distributed as D
    from jax.sharding import Mesh

    csr = scrambled(192, band=5, seed=15)
    d = csr.to_dense()
    mat = F.csr_to_spc5(csr, 1, 8)
    x = np.random.default_rng(6).standard_normal(192).astype(np.float32)
    tgt = d.astype(np.float64) @ x.astype(np.float64)
    devs = np.asarray(jax.devices()[:1])
    mesh = Mesh(devs, ("data",))
    for pr in (None, 16):
        sh = D.shard_matrix(mat, len(devs), mesh=mesh, pr=pr, xw=32, cb=8,
                            reorder="rcm", tune=False)
        assert sh.reorder == "rcm" and sh.col_perm is not None
        run = D.make_distributed_spmv(sh, mesh)
        y = np.asarray(run(jnp.asarray(x)))
        np.testing.assert_allclose(y, tgt, atol=2e-3)
    # no reorder: fields stay None, path unchanged
    sh0 = D.shard_matrix(mat, len(devs), mesh=mesh, tune=False)
    assert sh0.col_perm is None and sh0.reorder == ""


def test_records_carry_reorder_fields(tmp_path):
    """Record round-trip with the v2 reorder fields + tune() returning a
    config whose reorder prepare() then applies."""
    st_ = S.RecordStore()
    feats = S.MatrixFeatures(0, 0, 0, 5.0, 2.0, 4.0, 0.5)
    cfg = S.PanelConfig("panels", 16, 32, 8, reorder="rcm")
    for avg in (1.0, 4.0, 8.0):
        f = S.MatrixFeatures(0, 0, 0, 5.0, 2.0, avg, 0.5)
        st_.add_measurement("1x8", f, cfg, 1, 9.0, matrix="m",
                            bandwidth_post=3.0, nchunks=7)
        st_.add_measurement("1x8", f, S.PanelConfig("whole", 0, 0, 256), 1,
                            1.0)
    p = str(tmp_path / "r.jsonl")
    st_.save_jsonl(p)
    back = S.load_records(p)
    assert back.records == st_.records
    rec = [r for r in back.records if r.reorder][0]
    assert (rec.reorder, rec.bandwidth_post, rec.nchunks) == ("rcm", 3.0, 7)
    tuned = S.tune(feats, store=back, kernel="1x8")
    assert tuned.reorder == "rcm"
    # clamp preserves the strategy
    assert S.clamp_config(tuned, nrows=8, ncols=8, r=1, c=8,
                          nblocks=2).reorder == "rcm"
    # prepare consumes the tuned reorder end-to-end
    csr = scrambled(96, band=4, seed=17)
    mat = F.csr_to_spc5(csr, 1, 8)
    h = ops.prepare(mat, dtype=np.float32, store=back)
    assert h.is_reordered
    assert h.strategy == "rcm"
    x = np.random.default_rng(7).standard_normal(96).astype(np.float32)
    y = np.asarray(ops.spmv(h, jnp.asarray(x), use_pallas=False))
    np.testing.assert_allclose(y, csr.to_dense() @ x, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(20, 120),
    m=st.integers(20, 120),
    density=st.floats(0.03, 0.4),
    rc=st.sampled_from([(1, 8), (2, 4), (4, 4)]),
    strategy=st.sampled_from(["sigma", "rcm", "colwindow", "auto"]),
    seed=st.integers(0, 2**20),
)
def test_property_reorder_roundtrip(n, m, density, rc, strategy, seed):
    rng = np.random.default_rng(seed)
    d = ((rng.random((n, m)) < density)
         * rng.standard_normal((n, m))).astype(np.float32)
    csr = F.csr_from_dense(d)
    mat = F.csr_to_spc5(csr, *rc)
    x = rng.standard_normal(m).astype(np.float32)
    tgt = d.astype(np.float64) @ x.astype(np.float64)
    for layout in ("whole", "panels"):
        h = ops.prepare(mat, layout=layout, dtype=np.float32, pr=16, xw=24,
                        cb=4, reorder=strategy)
        y = np.asarray(ops.spmv(h, jnp.asarray(x), use_pallas=False))
        np.testing.assert_allclose(y, tgt, atol=2e-3)
